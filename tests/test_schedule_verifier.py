"""The SPMD collective-schedule verifier (repro/analysis/schedule).

Synthetic StableHLO fixtures pin the parser and the per-device scalar
evaluator — most importantly the planted-drop module, where a ``case``
branch on ``partition_id`` makes rank 0 skip a collective-permute the
other ranks issue: the textbook distributed hang, flagged with a
readable per-device diff.  (``lax.cond`` lowers the predicate to
``int(pred)`` selecting the case region, so region 0 is the FALSE
branch — the evaluator's branch resolution is pinned here too.)

The real-module test lowers ``parallel_fmm_evaluate`` for both plan
kinds (slab and block, including the degenerate single-rank-axis grids)
on 4 forced host devices in a subprocess and verifies every schedule is
consistent.
"""
import os
import subprocess
import sys
import textwrap

from repro.analysis import schedule as S

_MODULE_HEAD = ("module attributes {mhlo.num_partitions = 4 : i32, "
                "mhlo.num_replicas = 1 : i32} {")

# The planted drop: sel = int(partition_id == 0); case region 0 (false,
# ranks 1..3) issues the permute, region 1 (true, rank 0) skips it.
_DROP = _MODULE_HEAD + """
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = stablehlo.partition_id : tensor<ui32>
    %1 = stablehlo.convert %0 : (tensor<ui32>) -> tensor<i32>
    %2 = stablehlo.constant dense<0> : tensor<i32>
    %3 = stablehlo.compare  EQ, %1, %2 : (tensor<i32>, tensor<i32>) -> tensor<i1>
    %4 = stablehlo.convert %3 : (tensor<i1>) -> tensor<i32>
    %5 = "stablehlo.case"(%4) ({
      %6 = "stablehlo.collective_permute"(%arg0) {channel_handle = #stablehlo.channel_handle<handle = 1, type = 0>, source_target_pairs = dense<[[0, 1], [1, 2], [2, 3], [3, 0]]> : tensor<4x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
      stablehlo.return %6 : tensor<4xf32>
    }, {
      stablehlo.return %arg0 : tensor<4xf32>
    }) : (tensor<i32>) -> tensor<4xf32>
    return %5 : tensor<4xf32>
  }
}
"""


def test_planted_drop_is_flagged_with_readable_diff():
    rep = S.verify_schedule(_DROP, label="planted-drop")
    assert not rep.ok
    assert rep.ndev == 4
    assert len(rep.schedules[0]) == 0          # rank 0 skips
    assert all(len(s) == 1 for s in rep.schedules[1:])
    diff = rep.diff_text()
    assert "DIVERGENT" in diff
    assert "collective_permute" in diff
    assert "block in this collective forever" in diff
    # per-device sequences are enumerated so the hang is localizable
    assert "device 0: 0 collectives" in diff
    assert "device 1: 1 collectives" in diff


def test_per_device_branch_resolution_case_regions():
    """Region 0 is the FALSE branch: rank 0 (sel=1) runs region 1."""
    ev0, probs0 = S.extract_schedule(_DROP, device=0)
    ev2, probs2 = S.extract_schedule(_DROP, device=2)
    assert probs0 == [] and probs2 == []
    assert ev0 == []
    assert len(ev2) == 1 and ev2[0].kind == "collective_permute"
    assert ev2[0].pairs == ((0, 1), (1, 2), (2, 3), (3, 0))
    assert ev2[0].channel == 1


_CONSISTENT = _MODULE_HEAD + """
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {
    %0 = "stablehlo.collective_permute"(%arg0) {channel_handle = #stablehlo.channel_handle<handle = 1, type = 0>, source_target_pairs = dense<[[0, 1], [1, 2], [2, 3], [3, 0]]> : tensor<4x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
    %1 = "stablehlo.all_gather"(%0) {all_gather_dim = 0 : i64, channel_handle = #stablehlo.channel_handle<handle = 2, type = 0>, replica_groups = dense<[[0, 1, 2, 3]]> : tensor<1x4xi64>} : (tensor<4xf32>) -> tensor<16xf32>
    %2 = stablehlo.add %1, %1 : tensor<16xf32>
    return %0 : tensor<4xf32>
  }
}
"""


def test_consistent_module_passes_with_event_metadata():
    rep = S.verify_schedule(_CONSISTENT, label="consistent")
    assert rep.ok, rep.diff_text()
    seq = rep.schedules[0]
    assert [e.kind for e in seq] == ["collective_permute", "all_gather"]
    assert seq[1].groups == ((0, 1, 2, 3),)
    assert "CONSISTENT" in rep.diff_text()
    assert all(s == seq for s in rep.schedules)


_UNRESOLVED_SAME = _MODULE_HEAD + """
  func.func public @main(%arg0: tensor<4xf32>, %arg1: tensor<i32>) -> tensor<4xf32> {
    %0 = "stablehlo.case"(%arg1) ({
      %1 = "stablehlo.collective_permute"(%arg0) {source_target_pairs = dense<[[0, 1], [1, 0]]> : tensor<2x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
      stablehlo.return %1 : tensor<4xf32>
    }, {
      %1 = "stablehlo.collective_permute"(%arg0) {source_target_pairs = dense<[[0, 1], [1, 0]]> : tensor<2x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
      stablehlo.return %1 : tensor<4xf32>
    }) : (tensor<i32>) -> tensor<4xf32>
    return %0 : tensor<4xf32>
  }
}
"""


def test_unresolved_selector_accepted_when_regions_identical():
    """A data-dependent case whose regions issue IDENTICAL sequences is
    safe regardless of which region runs."""
    rep = S.verify_schedule(_UNRESOLVED_SAME, label="data-branch")
    assert rep.ok, rep.diff_text()
    assert all(len(s) == 1 for s in rep.schedules)


def test_unresolved_selector_with_divergent_regions_is_a_problem():
    """The same module with one region's permute dropped: the selector is
    not statically known, so the verifier must refuse (conservative)."""
    divergent = _UNRESOLVED_SAME.replace(
        """    }, {
      %1 = "stablehlo.collective_permute"(%arg0) {source_target_pairs = dense<[[0, 1], [1, 0]]> : tensor<2x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
      stablehlo.return %1 : tensor<4xf32>
    })""",
        """    }, {
      stablehlo.return %arg0 : tensor<4xf32>
    })""")
    assert divergent != _UNRESOLVED_SAME
    rep = S.verify_schedule(divergent, label="data-branch-divergent")
    assert not rep.ok
    assert any("unresolvable divergent" in p for p in rep.problems), \
        rep.problems


_WHILE_LOOP = _MODULE_HEAD + """
  func.func public @main(%arg0: tensor<4xf32>, %arg1: tensor<i32>) -> tensor<4xf32> {
    %0:2 = stablehlo.while(%iterArg = %arg1, %iterArg_0 = %arg0) : tensor<i32>, tensor<4xf32>
     cond {
      %1 = stablehlo.constant dense<3> : tensor<i32>
      %2 = stablehlo.compare  LT, %iterArg, %1 : (tensor<i32>, tensor<i32>) -> tensor<i1>
      stablehlo.return %2 : tensor<i1>
    } do {
      %1 = "stablehlo.collective_permute"(%iterArg_0) {source_target_pairs = dense<[[0, 1], [1, 2], [2, 3], [3, 0]]> : tensor<4x2xi64>} : (tensor<4xf32>) -> tensor<4xf32>
      %2 = stablehlo.constant dense<1> : tensor<i32>
      %3 = stablehlo.add %iterArg, %2 : tensor<i32>
      stablehlo.return %3, %1 : tensor<i32>, tensor<4xf32>
    }
    return %0#1 : tensor<4xf32>
  }
}
"""


def test_while_body_events_tagged_in_loop_and_consistent():
    rep = S.verify_schedule(_WHILE_LOOP, label="while")
    assert rep.ok, rep.diff_text()
    seq = rep.schedules[0]
    assert len(seq) == 1 and seq[0].in_loop
    assert "in_loop" in seq[0].brief()


def _sanity_module(attrs):
    return _MODULE_HEAD + f"""
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<4xf32> {{
    %0 = "stablehlo.collective_permute"(%arg0) {{{attrs}}} : (tensor<4xf32>) -> tensor<4xf32>
    return %0 : tensor<4xf32>
  }}
}}
"""


def test_event_sanity_duplicate_targets():
    rep = S.verify_schedule(_sanity_module(
        "source_target_pairs = dense<[[0, 1], [2, 1]]> : tensor<2x2xi64>"))
    assert not rep.ok
    assert any("duplicate targets" in p for p in rep.problems), rep.problems


def test_event_sanity_device_out_of_range():
    rep = S.verify_schedule(_sanity_module(
        "source_target_pairs = dense<[[0, 5]]> : tensor<1x2xi64>"))
    assert not rep.ok
    assert any("out of range" in p for p in rep.problems), rep.problems


def test_event_sanity_overlapping_replica_groups():
    mod = _MODULE_HEAD + """
  func.func public @main(%arg0: tensor<4xf32>) -> tensor<16xf32> {
    %0 = "stablehlo.all_gather"(%arg0) {all_gather_dim = 0 : i64, replica_groups = dense<[[0, 1], [1, 2]]> : tensor<2x2xi64>} : (tensor<4xf32>) -> tensor<16xf32>
    return %0 : tensor<16xf32>
  }
}
"""
    rep = S.verify_schedule(mod)
    assert not rep.ok
    assert any("overlap" in p for p in rep.problems), rep.problems


def test_ndev_read_from_module_attributes():
    rep = S.verify_schedule(_CONSISTENT)    # no explicit ndev
    assert rep.ndev == 4


# ---------------------------------------------------------------------------
# real modules: both plan kinds on 4 forced host devices
# ---------------------------------------------------------------------------

_MULTIDEVICE_BODY = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=4")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import numpy as np
    import jax
    from jax.sharding import Mesh

    from repro.analysis import schedule as S
    from repro.core import parallel_fmm as pf
    from repro.core import stepper as stp
    from repro.core.cost_model import ModelParams
    from repro.core.plan import block_plan_from_counts, plan_from_counts
    from repro.core.quadtree import build_tree

    level, p = 3, 4
    rng = np.random.default_rng(0)
    pos = rng.uniform(0.05, 0.95, size=(400, 2))
    tree, index = build_tree(pos, rng.normal(size=400), level, sigma=0.02)
    params = ModelParams(level=level, cut=2, p=p, slots=tree.slots)
    mesh = Mesh(np.array(jax.devices()[:4]), ("data",))

    plans = {"slab": plan_from_counts(index.counts, params, 4,
                                      method="model")}
    for grid in ((2, 2), (4, 1), (1, 4)):
        plans[f"block{grid[0]}x{grid[1]}"] = block_plan_from_counts(
            index.counts, params, grid, method="model")

    evaluate = pf.TRACE_ENTRY_POINTS["parallel_fmm_evaluate"]
    for label, plan in plans.items():
        rep = S.verify_entry(evaluate, tree, p, mesh, plan=plan, ndev=4,
                             label=label)
        assert rep.ok, rep.diff_text()
        assert len(rep.schedules[0]) > 0, label   # sharded paths collect
    rep = S.verify_entry(stp.TRACE_ENTRY_POINTS["rk2_step"], tree, 1e-4,
                         p=p, mesh=mesh, plan=plans["slab"], ndev=4,
                         label="rk2_step")
    assert rep.ok, rep.diff_text()
    print("OK")
""")


def test_real_modules_verify_on_four_devices():
    """Both plan kinds (slab + block, incl. degenerate single-rank axes)
    and the sharded stepper all produce consistent per-device schedules."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _MULTIDEVICE_BODY],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
