"""FMM-as-a-service: the batched multi-tenant serving engine (DESIGN.md §15).

Pins the PR 10 acceptance criteria on a single device (the 4-device
multi-tenant drill runs ``examples/fmm_serve_demo.py`` in a subprocess —
jax locks the device count at first init):

* admission is priced BEFORE any device work: an oversized job raises a
  typed :class:`JobRejected` carrying its Eq 13-15 :class:`JobPrice`, and
  backlog overflow defers (then promotes) instead of deadlocking;
* bin-packed vmap batches return exactly what the single-tenant library
  returns — batched == serial ``fmm_evaluate``, probe-grid one-shots ==
  the f64 ``direct_sum`` oracle (laplace potential compared on Re: the
  imaginary part of the complex log carries branch-cut ambiguity);
* steady-state serving never retraces: fresh tenant data rides the
  compiled bucket programs, pinned via ``batched_cache_entries``;
* the shared :class:`ArtifactCache` amortizes trees/plans across repeat
  jobs and session steps with exact hit/miss counter pins, and a
  ``from_checkpoint``-restored session steps without retracing
  ``rk2_step`` (the PR 8 numpy-leaf foot-gun, guarded at the boundary).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import equations as eqs
from repro.core.cost_model import array_digest, batch_padding_stats
from repro.core.fmm import fmm_evaluate
from repro.core.quadtree import build_tree, gather_particle_values
from repro.serve import fmm_service as svc
from repro.serve.fmm_service import (ArtifactCache, FmmJob, FmmServiceEngine,
                                     JobRejected, ServiceBudget)

SIGMA = 0.02


def _sources(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.uniform(0.1, 0.9, size=(n, 2)), rng.normal(size=n)


# ---------------------------------------------------------------------------
# Pricing + admission control
# ---------------------------------------------------------------------------


def test_oversized_job_rejected_with_price():
    """The budget blow-up path: typed rejection carrying the cost-model
    price, computed without touching the device or building any tree."""
    engine = FmmServiceEngine(budget=ServiceBudget(max_job_flops=1.0))
    pos, q = _sources(200)
    with pytest.raises(JobRejected, match="exceeds max_job_flops") as ei:
        engine.submit(FmmJob(positions=pos, strength=q, sigma=SIGMA))
    price = ei.value.price
    assert price.total_flops > 1.0
    assert price.level >= 2 and price.p == eqs.VORTEX.default_p
    assert engine.counters["rejected"] == 1
    assert engine.counters["admitted"] == 0
    # pricing is pure host arithmetic: nothing was built or executed
    assert engine.cache.stats() == {"entries": 0, "hits": 0, "misses": 0}
    assert engine.results == {}


def test_session_pricing_scales_with_steps():
    engine = FmmServiceEngine(budget=ServiceBudget(max_job_flops=1e-3))
    pos, q = _sources(100)
    with pytest.raises(JobRejected) as ei:
        engine.submit(FmmJob(positions=pos, strength=q, steps=5, sigma=SIGMA))
    price = ei.value.price
    assert price.lane == "session" and price.steps == 5
    # RK2 = two evaluations per step
    assert price.total_flops == pytest.approx(10 * price.flops_per_eval)


def test_backlog_defers_then_promotes():
    """max_queue_flops bounds the admitted backlog; deferred jobs are
    promoted as the queue drains, and drain() always completes them."""
    engine = FmmServiceEngine()
    pos, q = _sources(60, seed=1)
    first = engine.submit(FmmJob(positions=pos, strength=q, p=4, sigma=SIGMA))
    per_job = engine.queue[0].price.total_flops
    engine.budget = ServiceBudget(max_queue_flops=1.5 * per_job)
    later = [engine.submit(FmmJob(positions=pos,
                                  strength=q * (i + 2), p=4, sigma=SIGMA))
             for i in range(2)]
    assert engine.counters["deferred"] == 2
    assert len(engine.queue) == 1 and len(engine.deferred) == 2
    results = engine.drain()
    assert engine.counters["promoted"] == 2
    assert not engine.queue and not engine.deferred
    assert set(results) == {first, *later}


def test_resolve_job_spec_errors():
    assert eqs.resolve_job_spec("vortex", steps=3) is eqs.VORTEX
    assert eqs.resolve_job_spec("tracer", have_targets=True) is eqs.TRACER
    with pytest.raises(ValueError, match="target"):
        eqs.resolve_job_spec("tracer", have_targets=False)
    with pytest.raises(ValueError, match="evaluation-only"):
        eqs.resolve_job_spec("laplace", have_targets=True, steps=2)


def test_batch_padding_stats_math():
    s = batch_padding_stats(100.0, 3, 4)
    assert s["paid"] == 400.0 and s["useful"] == 300.0
    assert s["padding_waste"] == 100.0
    assert s["utilization"] == pytest.approx(0.75)
    assert batch_padding_stats(0.0, 0, 0)["utilization"] == 1.0


def test_array_digest_keys_by_value():
    a = np.arange(6, dtype=np.float64)
    assert array_digest(a) == array_digest(a.copy())
    assert array_digest(a) != array_digest(a + 1)
    assert array_digest(a) != array_digest(a.astype(np.float32))
    assert array_digest(a) != array_digest(a.reshape(2, 3))
    assert array_digest(a, a) != array_digest(a)


# ---------------------------------------------------------------------------
# Batched lane correctness
# ---------------------------------------------------------------------------


def test_batched_jobs_match_serial_evaluation():
    """Two nearby-size vortex jobs share one bucket, run as ONE vmap batch,
    and return exactly what single-tenant serial evaluation returns."""
    engine = FmmServiceEngine()
    pos0, q0 = _sources(150, seed=10)
    # same layout, different charges: same bucket, distinct cached trees
    jobs = [(pos0, q0), (pos0, -2.0 * q0)]
    jids = [engine.submit(FmmJob(positions=pos, strength=q, p=8, sigma=SIGMA))
            for pos, q in jobs]
    engine.drain()
    assert engine.counters["batches"] == 1
    for jid, (pos, q) in zip(jids, jobs):
        r = engine.result(jid)
        assert r.lane == "batched" and r.batch_capacity == 2
        tree, index = build_tree(pos, q, r.price.level, SIGMA,
                                 slots=r.price.slots)
        ref = gather_particle_values(
            np.asarray(fmm_evaluate(svc.ensure_device(tree), r.price.p)),
            index)
        err = np.abs(r.out - ref).max() / np.abs(ref).max()
        assert err < 1e-5, err


def test_probe_jobs_match_direct_sum():
    """laplace + tracer probe-grid one-shots vs the f64 oracle."""
    engine = FmmServiceEngine()
    src, q = _sources(160, seed=3)
    tgt = np.random.default_rng(4).uniform(0.15, 0.85, size=(48, 2))
    jids = {name: engine.submit(FmmJob(
        positions=src, strength=q, equation=name, targets=tgt, p=12,
        sigma=SIGMA)) for name in ("laplace", "tracer")}
    engine.drain()
    zt, zs = tgt[:, 0] + 1j * tgt[:, 1], src[:, 0] + 1j * src[:, 1]
    for name, jid in jids.items():
        out = engine.result(jid).out
        ref = eqs.direct_sum(name, zt, zs, q, SIGMA)
        if name == "laplace":
            err = max(np.abs(out[:, 0].real - ref[:, 0].real).max()
                      / np.abs(ref[:, 0].real).max(),
                      np.abs(out[:, 1] - ref[:, 1]).max()
                      / np.abs(ref[:, 1]).max())
        else:
            err = np.abs(out - ref).max() / np.abs(ref).max()
        assert err < 2e-3, (name, err)


def test_steady_state_serving_never_retraces():
    """Second wave, same layouts, FRESH strengths: zero new jit entries."""
    engine = FmmServiceEngine()
    pos, q = _sources(150, seed=20)
    rng = np.random.default_rng(21)
    for wave in range(3):
        # same wave width each time: the padded batch axis is part of the
        # compiled shape, so steady state means same-capacity waves
        for _ in range(3):
            engine.submit(FmmJob(positions=pos,
                                 strength=rng.normal(size=len(q)),
                                 p=8, sigma=SIGMA))
        engine.drain()
        if wave == 0:
            warm = svc.batched_cache_entries()
    assert svc.batched_cache_entries() == warm


def test_service_boundary_device_puts_host_leaves():
    """stack_trees / ensure_device must hand jit entries DEVICE arrays:
    raw numpy leaves key a separate cache entry per request (PR 8)."""
    import jax

    pos, q = _sources(80, seed=5)
    tree, _ = build_tree(pos, q, 2, SIGMA, slots=32)
    host = tree.__class__(z=np.asarray(tree.z), q=np.asarray(tree.q),
                          mask=np.asarray(tree.mask), level=tree.level,
                          sigma=tree.sigma)
    for leaf in svc.stack_trees([host, host], 4):
        assert isinstance(leaf, jax.Array)
    dev = svc.ensure_device(host)
    assert all(isinstance(x, jax.Array) for x in (dev.z, dev.q, dev.mask))


# ---------------------------------------------------------------------------
# Artifact cache amortization
# ---------------------------------------------------------------------------


def test_oneshot_tree_cache_hits_and_misses():
    engine = FmmServiceEngine()
    pos, q = _sources(120, seed=30)
    job = dict(positions=pos, strength=q, p=6, sigma=SIGMA)
    engine.submit(FmmJob(**job))
    engine.drain()
    assert engine.cache.stats() == {"entries": 1, "hits": 0, "misses": 1}
    # identical resubmission: the tree is amortized, not rebuilt
    engine.submit(FmmJob(**job))
    engine.drain()
    assert engine.cache.stats() == {"entries": 1, "hits": 1, "misses": 1}
    # changed charge values -> new digest -> legitimate rebuild
    engine.submit(FmmJob(**{**job, "strength": q + 1.0}))
    engine.drain()
    assert engine.cache.stats()["misses"] == 2
    # same charges, different equation -> different charge_scale -> rebuild
    engine.submit(FmmJob(**{**job, "equation": "laplace", "p": 6}))
    engine.drain()
    assert engine.cache.stats()["misses"] == 3


def test_session_steps_amortize_through_shared_cache():
    """Open = tree + plan misses; every steady step re-resolves both keys
    as pure hits (the engine owns the artifacts, the session holds keys)."""
    engine = FmmServiceEngine()
    pos, q = _sources(100, seed=31)
    sid = engine.submit(FmmJob(positions=pos, strength=0.1 * q, steps=3,
                               p=4, dt=1e-3, sigma=SIGMA))
    assert engine.counters["sessions"] == 1
    stats0 = engine.cache.stats()
    assert stats0["misses"] == 2 and stats0["hits"] == 0
    for k in range(1, 4):
        engine.step_session(sid)
        s = engine.cache.stats()
        assert s["misses"] == 2, s
        assert s["hits"] == 2 * k, s
    assert engine.counters["session_steps"] == 3
    assert engine.stats()["latency"]["session"]["n"] == 3


def test_restored_session_steps_without_retrace(tmp_path):
    """from_checkpoint through the engine: restored leaves are device-put
    (``_adopt_restored``), so the first post-restore step is a pure
    rk2_step cache HIT — the numpy-leaf restore foot-gun stays guarded
    behind the service boundary."""
    from repro.core import stepper as stp

    engine = FmmServiceEngine(
        session_kwargs={"checkpoint_dir": str(tmp_path)})
    pos, q = _sources(100, seed=32)
    sid = engine.submit(FmmJob(positions=pos, strength=0.1 * q, steps=2,
                               p=4, dt=1e-3, sigma=SIGMA))
    engine.step_session(sid)
    engine.session(sid).stepper.save_checkpoint()
    engine.session(sid).stepper._ckpt.wait()    # saves are async

    rid = engine.restore_session(str(tmp_path))
    assert rid != sid
    entries = stp.rk2_step._cache_size()
    rec = engine.step_session(rid)
    assert stp.rk2_step._cache_size() == entries, \
        "post-restore step retraced rk2_step"
    assert rec.step >= 1
    # the restored trajectory continues the original one
    a, _ = engine.session(rid).particles()
    engine.step_session(sid)
    b, _ = engine.session(sid).particles()
    np.testing.assert_allclose(a, b, rtol=0, atol=1e-6)


# ---------------------------------------------------------------------------
# Streaming + observability
# ---------------------------------------------------------------------------


def test_stream_prefetch_yields_every_step():
    engine = FmmServiceEngine()
    pos, q = _sources(90, seed=33)
    sid = engine.submit(FmmJob(positions=pos, strength=0.1 * q, steps=3,
                               p=4, dt=1e-3, sigma=SIGMA))
    seen = [(i, rec.step) for i, _pos, rec in
            engine.session(sid).stream(3, prefetch=True)]
    assert [i for i, _ in seen] == [0, 1, 2]
    assert engine.counters["session_steps"] == 3


def test_stats_shape():
    engine = FmmServiceEngine()
    pos, q = _sources(110, seed=34)
    engine.submit(FmmJob(positions=pos, strength=q, p=6, sigma=SIGMA))
    engine.drain()
    s = engine.stats()
    assert s["batched_jobs"] == 1 and s["batches"] == 1
    assert 0.0 < s["batch_utilization"] <= 1.0
    assert s["latency"]["batched"]["n"] == 1
    assert s["jit_entries"] == svc.batched_cache_entries()


def test_serve_engine_dead_api_removed():
    """Satellite: the LM ServeEngine scaffold carried submit/_admit/slots
    bookkeeping that step_all never consulted — gone, not half-wired."""
    from repro.serve.engine import ServeEngine

    for name in ("submit", "_admit"):
        assert not hasattr(ServeEngine, name), name
    assert callable(ServeEngine.step_all)
    assert "ONLY serving API" in ServeEngine.__doc__


def test_artifact_cache_counters():
    c = ArtifactCache()
    assert c.get("k", lambda: 41) == 41
    assert c.get("k", lambda: 42) == 41
    assert "k" in c and len(c) == 1
    assert c.stats() == {"entries": 1, "hits": 1, "misses": 1}
    c.clear()
    assert len(c) == 0 and c.stats()["hits"] == 1


# ---------------------------------------------------------------------------
# The 4-device multi-tenant drill (acceptance)
# ---------------------------------------------------------------------------


def test_multitenant_drill_four_devices():
    """examples/fmm_serve_demo.py end to end: >= 3 concurrent tenants
    (two streamed vortex sessions + laplace/tracer probe waves), all
    matching single-tenant references, oversized job rejected with its
    price, steady state retrace-free — on a 4-device host mesh."""
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "examples", "fmm_serve_demo.py"),
         "--devices", "4", "--n", "220", "--steps", "2", "--p", "6"],
        capture_output=True, text=True, env=env, cwd=root, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "fmm_serve_demo: OK" in proc.stdout
    assert "rejected as priced" in proc.stdout
    assert "steady-state retraces: 0" in proc.stdout
