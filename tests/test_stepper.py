"""Device-side rebinning + the dynamic RK2 stepper (paper §3 + §4 dynamic).

Pins the acceptance criterion: a jitted RK2 step via ``rebuild_tree`` +
``VortexStepper`` reproduces the host-rebuild loop it replaces to f32
tolerance, overflow is reported (never silently corrupted), and the
occupancy guard re-levels before ``build_tree`` could die mid-run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.fmm import fmm_velocity
from repro.core.quadtree import (build_tree, gather_particle_values,
                                 rebuild_tree)
from repro.core.stepper import VortexStepper, rk2_step
from repro.core.vortex import lamb_oseen_particles


def _random_tree(n=500, level=4, slots=12, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(0.01, 0.99, (n, 2))
    gamma = rng.normal(size=n)
    tree, index = build_tree(pos, gamma, level, sigma=0.02, slots=slots)
    return tree, index, pos, gamma


# ---------------------------------------------------------------------------
# rebuild_tree: the jit-able build_tree
# ---------------------------------------------------------------------------


def test_rebuild_identity_matches_build_tree():
    tree, index, _, _ = _random_tree()
    new_tree, aux, ok = jax.jit(rebuild_tree)(tree, tree.z)
    assert bool(ok) and aux is None
    assert (np.asarray(new_tree.mask.sum(-1)) == index.counts).all()
    # same multiset of particles per box (slot order may differ)
    for a, b in ((new_tree.z, tree.z), (new_tree.q, tree.q)):
        assert np.allclose(np.sort(np.asarray(a), axis=-1),
                           np.sort(np.asarray(b), axis=-1))


def test_rebuild_moved_matches_host_binning():
    tree, index, pos, gamma = _random_tree(seed=1)
    rng = np.random.default_rng(2)
    pos2 = (pos + rng.normal(0, 0.05, pos.shape)).clip(0.001, 0.999)
    host_tree, host_index = build_tree(pos2, gamma, tree.level, sigma=0.02,
                                       slots=tree.slots)
    n = tree.nside
    newz = np.zeros((n * n, tree.slots), dtype=np.complex64)
    newz[index.box_of_particle, index.slot_of_particle] = \
        pos2[:, 0] + 1j * pos2[:, 1]
    new_tree, _, ok = rebuild_tree(tree, jnp.asarray(newz.reshape(n, n, -1)))
    assert bool(ok)
    assert (np.asarray(new_tree.mask.sum(-1)) == host_index.counts).all()
    assert np.asarray(new_tree.q).sum() == pytest.approx(
        np.asarray(host_tree.q).sum(), rel=1e-5)


def test_rebuild_reports_overflow():
    tree, _, _, _ = _random_tree(slots=None)   # slots == max occupancy
    clumped = jnp.full_like(tree.z, 0.5 + 0.5j)
    overflowed, _, ok = rebuild_tree(tree, clumped)
    assert not bool(ok)
    # capacity is respected even under overflow (surplus dropped, not UB)
    assert int(overflowed.mask.sum()) <= overflowed.slots


def test_rebuild_carries_aux_payload():
    tree, _, _, _ = _random_tree(seed=5)
    labels = jnp.where(tree.mask,
                       jnp.cumsum(tree.mask.reshape(-1)).reshape(tree.mask.shape),
                       0)
    shifted = jnp.where(tree.mask, tree.z + 0.03, tree.z)
    new_tree, (new_labels,), ok = rebuild_tree(tree, shifted, aux=(labels,))
    assert bool(ok)
    # every label survives, attached to its particle
    a = np.sort(np.asarray(labels)[np.asarray(tree.mask)])
    b = np.sort(np.asarray(new_labels)[np.asarray(new_tree.mask)])
    assert (a == b).all()


# ---------------------------------------------------------------------------
# Jitted RK2 == host-rebuild loop (acceptance-pinned)
# ---------------------------------------------------------------------------


def test_jitted_rk2_matches_host_rebuild_loop():
    pos0, gamma0, sigma = lamb_oseen_particles(40)
    p, dt, steps = 10, 0.004, 3
    st = VortexStepper(pos0, gamma0, sigma, p=p, dt=dt,
                       payload={"z0": pos0[:, 0] + 1j * pos0[:, 1]})
    for _ in range(steps):
        st.step()

    # the loop examples/vortex_sim.py used to run: host build_tree twice
    # per RK2 step at the same level / slot capacity
    level, slots = st.params.level, st.params.slots
    pos = pos0.copy()
    for _ in range(steps):
        t, ix = build_tree(pos, gamma0, level, sigma, slots=slots)
        w = gather_particle_values(np.asarray(fmm_velocity(t, p)), ix)
        mid = pos + 0.5 * dt * np.stack([w.real, -w.imag], 1)
        t, ix = build_tree(mid, gamma0, level, sigma, slots=slots)
        w = gather_particle_values(np.asarray(fmm_velocity(t, p)), ix)
        pos = pos + dt * np.stack([w.real, -w.imag], 1)

    # match trajectories via the initial-position payload
    m = np.asarray(st.tree.mask).reshape(-1)
    z_dev = np.asarray(st.tree.z).reshape(-1)[m]
    z0_dev = np.asarray(st.payload["z0"]).reshape(-1)[m]
    dev = z_dev[np.lexsort((z0_dev.imag, z0_dev.real))]
    z0_host = pos0[:, 0] + 1j * pos0[:, 1]
    host = (pos[:, 0] + 1j * pos[:, 1])[np.lexsort((z0_host.imag,
                                                    z0_host.real))]
    assert len(dev) == len(host)
    assert np.abs(dev - host).max() < 5e-5


def test_stepper_orbit_invariant():
    """Lamb-Oseen particles orbit on near-circles through many rebins."""
    pos0, gamma0, sigma = lamb_oseen_particles(40)
    r0 = np.hypot(pos0[:, 0] - 0.5, pos0[:, 1] - 0.5)
    st = VortexStepper(pos0, gamma0, sigma, p=10, dt=0.005,
                       payload={"r0": r0 + 0j})
    for _ in range(4):
        st.step()
    m = np.asarray(st.tree.mask).reshape(-1)
    z = np.asarray(st.tree.z).reshape(-1)[m]
    rr0 = np.asarray(st.payload["r0"]).reshape(-1)[m].real
    r = np.hypot(z.real - 0.5, z.imag - 0.5)
    sel = rr0 > 0.02
    assert np.abs(r[sel] - rr0[sel]).max() < 5e-3


# ---------------------------------------------------------------------------
# Occupancy guard: re-level instead of dying inside build_tree mid-run
# ---------------------------------------------------------------------------


def test_occupancy_guard_relevels_before_overflow():
    pos0, gamma0, sigma = lamb_oseen_particles(40)
    st = VortexStepper(pos0, gamma0, sigma, p=8, dt=0.004,
                       slots_headroom=1.0,       # no slack: occ == slots
                       occupancy_guard=0.9,
                       payload={"z0": pos0[:, 0] + 1j * pos0[:, 1]})
    n_before = int(st.tree.mask.sum())
    level_before = st.params.level
    assert st.maybe_replan() == "relevel"         # guard fires -> re-level
    assert int(st.tree.mask.sum()) == n_before    # no particle lost
    assert st.params.slots >= st.counts().max()
    # payload survived the host rebuild
    z0 = np.asarray(st.payload["z0"]).reshape(-1)
    assert (z0 != 0).sum() == n_before
    assert st.params.level >= level_before


def test_stepper_measured_times_fn_is_wired():
    """The dynamic loop polls the injected per-device timer at replan time
    (the hook real deployments use for heterogeneous pools)."""
    pos0, gamma0, sigma = lamb_oseen_particles(40)
    calls = []

    def timer(stepper):
        calls.append(stepper.step_count)
        return np.ones(stepper.nparts)

    st = VortexStepper(pos0, gamma0, sigma, p=8, dt=0.004, dynamic=True,
                       replan_every=1, measured_times_fn=timer)
    st.step()
    assert calls == [1]


# ---------------------------------------------------------------------------
# Wall-clock sample hygiene for the measured-feedback replanner
# ---------------------------------------------------------------------------


def test_clean_wall_samples_drops_every_retrace_successor():
    """Regression (substep-pipelining PR): ANY adopted tree change —
    replan, occupancy-guard re-level, recovery rung — retraces on the
    FOLLOWING step, so the flagged record AND its successor must both be
    dropped from the feedback window, not only replan successors."""
    from repro.core.stepper import StepRecord, clean_wall_samples

    def rec(step, sec, replanned=False, releveled=False, recovered=""):
        return StepRecord(step=step, seconds=sec, load_balance=1.0,
                          replanned=replanned, releveled=releveled,
                          level=5, recovered=recovered)

    records = [rec(1, 1.0),
               rec(2, 9.0, replanned=True),     # flagged
               rec(3, 9.0),                     # retrace successor
               rec(4, 1.1),
               rec(5, 9.0, releveled=True),     # guard re-level: flagged too
               rec(6, 9.0),                     # its retrace successor
               rec(7, 1.2),
               rec(8, 9.0, recovered="expand_domain"),
               rec(9, 9.0),                     # recovery retrace
               rec(10, 1.3)]
    assert clean_wall_samples(records) == [1.0, 1.1, 1.2, 1.3]
    # flagged-first window: the leading record itself is dropped
    assert clean_wall_samples([rec(1, 9.0, releveled=True),
                               rec(2, 9.0), rec(3, 1.0)]) == [1.0]
    assert clean_wall_samples([]) == []


def test_occupancy_guard_relevel_is_recorded_as_relevel():
    """Regression: the guard's re-level used to come back as a bare True
    and was recorded as ``replanned`` — mislabeling the record and keeping
    its inflated wall sample in the feedback window."""
    pos0, gamma0, sigma = lamb_oseen_particles(40)
    st = VortexStepper(pos0, gamma0, sigma, p=8, dt=0.004,
                       slots_headroom=1.0, occupancy_guard=0.9,
                       dynamic=True, replan_every=1)
    rec = st.step()
    assert rec.releveled and not rec.replanned
    from repro.core.stepper import clean_wall_samples
    assert clean_wall_samples(st.history) == []
