"""Trainer, checkpointing/fault-tolerance, pipeline, optimizer behaviour."""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import get_smoke_config
from repro.data.pipeline import PipelineState, advance, make_batch
from repro.models.config import ShapeConfig
from repro.optim.adamw import (AdamWConfig, apply_updates, compress_decompress,
                               init_state, schedule)
from repro.train.loop import Trainer, TrainerConfig

TINY = ShapeConfig("tiny", "train", seq_len=32, global_batch=2)


def _trainer(tmpdir, arch="yi_6b", steps=6, ckpt_every=3, **kw):
    cfg = get_smoke_config(arch)
    tcfg = TrainerConfig(steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmpdir), log_every=100, **kw)
    return Trainer(cfg, TINY, AdamWConfig(lr=1e-3, total_steps=steps), tcfg)


def test_trainer_runs_and_metrics_sane(tmp_path):
    tr = _trainer(tmp_path, steps=8, ckpt_every=0)
    log = tr.run()
    assert len(log) == 8
    losses = [m["loss"] for m in log]
    assert all(np.isfinite(losses))
    # random uniform tokens -> loss near ln(V) at init
    assert abs(losses[0] - np.log(tr.cfg.vocab)) < 1.0
    assert all(m["grad_norm"] > 0 for m in log)


def test_overfits_fixed_batch():
    """Repeatedly stepping one batch must drive the loss down (end-to-end
    gradient correctness through scan + remat + chunked CE)."""
    from repro.train.loop import make_train_step
    from repro.models.transformer import init_params
    from repro.data.pipeline import make_inputs
    cfg = get_smoke_config("yi_6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=3e-3, total_steps=30, warmup_steps=0)
    opt = init_state(params, ocfg)
    step = jax.jit(make_train_step(cfg, None, ocfg, q_chunk=16, loss_chunk=16))
    batch = make_inputs(PipelineState(seed=0, step=0), cfg, TINY)
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 1.0, losses[:3] + losses[-3:]


def test_checkpoint_restore_resumes_exactly(tmp_path):
    tr1 = _trainer(tmp_path, steps=6, ckpt_every=3)
    tr1.run()
    tr1.ckpt.wait()
    assert tr1.ckpt.latest_step() == 6

    # fresh trainer, same dir -> restores step 6 state and pipeline position
    tr2 = _trainer(tmp_path, steps=6, ckpt_every=3)
    assert tr2.try_restore()
    assert int(tr2.opt_state["step"]) == 6
    assert tr2.pipeline.step == 6
    for a, b in zip(jax.tree.leaves(tr1.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_survives_partial_write(tmp_path):
    """A crash mid-save (stale .tmp dir) must not break restore."""
    tr = _trainer(tmp_path, steps=3, ckpt_every=3)
    tr.run()
    tr.ckpt.wait()
    # simulate a crashed later save
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    (tmp_path / "step_99.tmp" / "params.npz").write_bytes(b"garbage")
    tr2 = _trainer(tmp_path, steps=3, ckpt_every=3)
    assert tr2.try_restore()
    assert int(tr2.opt_state["step"]) == 3


def test_checkpoint_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"w": jnp.ones((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": tree})
    assert mgr.all_steps() == [3, 4]
    out, meta = mgr.restore({"params": tree})
    assert meta["step"] == 4


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(1, {"params": {"w": jnp.ones((4,))}})
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"params": {"w": jnp.ones((5,))}})


def test_pipeline_deterministic_and_restart_safe():
    cfg = get_smoke_config("yi_6b")
    s0 = PipelineState(seed=7, step=3)
    a1, l1 = make_batch(s0, cfg, 4, 16)
    a2, l2 = make_batch(PipelineState(seed=7, step=3), cfg, 4, 16)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    s1 = advance(s0)
    b1, _ = make_batch(s1, cfg, 4, 16)
    assert not np.array_equal(np.asarray(a1), np.asarray(b1))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(l1[:, :-1]), np.asarray(a1[:, 1:]))
    assert (np.asarray(l1[:, -1]) == -1).all()


def test_gradient_accumulation_matches_full_batch():
    """n microbatches must reproduce the single-batch gradient step."""
    from repro.train.loop import make_train_step
    cfg = get_smoke_config("yi_6b")
    from repro.models.transformer import init_params
    params = init_params(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(lr=1e-3, total_steps=10)
    opt = init_state(params, ocfg)
    from repro.data.pipeline import make_inputs
    batch = make_inputs(PipelineState(seed=0, step=0), cfg,
                        ShapeConfig("t", "train", 32, 4))
    s1 = jax.jit(make_train_step(cfg, None, ocfg, num_microbatches=1,
                                 q_chunk=16, loss_chunk=16))
    s4 = jax.jit(make_train_step(cfg, None, ocfg, num_microbatches=4,
                                 q_chunk=16, loss_chunk=16))
    p1, _, m1 = s1(params, opt, batch)
    p4, _, m4 = s4(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-5)
    assert float(schedule(cfg, jnp.int32(55))) < 1.0


def test_compression_error_feedback_converges():
    """EF-int8: accumulated error feedback keeps the mean update unbiased."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(g_true)
    acc = jnp.zeros_like(g_true)
    n = 200
    for _ in range(n):
        g_hat, err = compress_decompress(g_true, err)
        acc = acc + g_hat
    # time-averaged compressed signal ~ true gradient
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true),
                               atol=2e-2)


def test_bf16_optimizer_state_still_trains(tmp_path):
    cfg = get_smoke_config("yi_6b")
    ocfg = AdamWConfig(lr=1e-3, total_steps=8, state_dtype="bfloat16")
    tcfg = TrainerConfig(steps=6, ckpt_every=0, ckpt_dir=str(tmp_path))
    tr = Trainer(cfg, TINY, ocfg, tcfg)
    log = tr.run()
    assert log[-1]["loss"] < log[0]["loss"]
    assert jax.tree.leaves(tr.opt_state["mu"])[0].dtype == jnp.bfloat16


def test_elastic_restore_onto_different_mesh(tmp_path):
    """Checkpoint written on 1 device restores onto an 8-device mesh with
    the framework's shardings (elastic scaling path).  Subprocess because
    jax locks the device count at first init."""
    import subprocess
    import sys
    import textwrap

    tr = _trainer(tmp_path, steps=3, ckpt_every=3)
    tr.run()
    tr.ckpt.wait()

    body = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np
        import jax
        from repro.checkpoint.manager import CheckpointManager
        from repro.configs.registry import get_smoke_config
        from repro.models.transformer import init_params
        from repro.parallel import sharding as shd

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke_config("yi_6b")
        template = init_params(jax.random.PRNGKey(0), cfg)
        pshard = shd.param_shardings(mesh, template)
        mgr = CheckpointManager({str(tmp_path)!r})
        out, meta = mgr.restore({{"params": template}},
                                shardings={{"params": pshard}})
        assert meta["step"] == 3
        # every leaf is actually placed with the target sharding
        leaf = out["params"]["embed"]
        assert len(leaf.sharding.device_set) >= 1
        total = sum(float(np.abs(np.asarray(x)).sum())
                    for x in jax.tree.leaves(out["params"]))
        assert np.isfinite(total) and total > 0
        print("OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", body],
                          capture_output=True, text=True, env=env, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK" in proc.stdout
